"""Padding-invariance properties of geometry-bucketed selector programs.

A :class:`~repro.core.space.PaddedSpace` right-pads a space's ``points`` /
``thresholds`` (and its job's tables) to fixed bucket widths so that one
compiled selector serves every member geometry of the bucket.  The contract
pinned here is that padding is *pure representation*: for any small space
and any bucket that holds it,

1. ``select_next`` on the padded space picks the same point index — and the
   same billed timeout τ — as on the native space (the padded selector is
   the native selector, bit for bit, on every decision);
2. no masked decision can ever land on a padding lane: the candidate
   argmax, the budget filter Γ, and the incumbent fallback all ignore the
   padding tail whatever garbage values it carries;
3. ``pad_to`` rejects buckets narrower than the native geometry.

Runs under real hypothesis when installed; under the deterministic
``_hypothesis_fallback`` shim otherwise, or when REPRO_NO_HYPOTHESIS is set
(scripts/ci.sh forces the fallback so both code paths stay covered).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    if os.environ.get("REPRO_NO_HYPOTHESIS"):
        raise ImportError("fallback forced by REPRO_NO_HYPOTHESIS")
    from hypothesis import given, settings, strategies as st
except ImportError:          # no-network CI: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (GeometryBucket, Settings, acquisition as acq,
                        make_selector)
from repro.core.space import DiscreteSpace, next_pow2
from repro.jobs import synthetic_job


def _padded_state(job, bucket, y, mask, cens=None):
    m = job.space.n_points
    yp = np.zeros(bucket.m, np.float32)
    mp = np.zeros(bucket.m, bool)
    yp[:m], mp[:m] = y, mask
    cp = None if cens is None else np.pad(cens, (0, bucket.m - m))
    return yp, mp, cp


def _observe(job, n, seed):
    rng = np.random.default_rng(seed)
    idx = rng.choice(job.space.n_points, min(n, job.space.n_points),
                     replace=False)
    y = np.zeros(job.space.n_points, np.float32)
    mask = np.zeros(job.space.n_points, bool)
    y[idx] = job.cost.astype(np.float32)[idx]
    mask[idx] = True
    return y, mask


# --------------------------------------------------------------------------- #
# 1. padded selection == native selection
# --------------------------------------------------------------------------- #
@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 50), n_a=st.integers(3, 6), n_b=st.integers(2, 4),
       extra=st.integers(0, 20))
def test_select_next_padding_invariant(seed, n_a, n_b, extra):
    """Random small spaces x random pad widths: the padded selector picks
    the native selector's point index (and agreement on the Γ-empty flag),
    for a lookahead policy and a greedy one."""
    job = synthetic_job(seed, n_a=n_a, n_b=n_b)
    m = job.space.n_points
    bucket = GeometryBucket(m=next_pow2(m) + extra, f=job.space.n_dims + 1,
                            t=int(job.space.thresholds.shape[1]) + 2)
    y, mask = _observe(job, n=max(3, m // 4), seed=seed)
    beta = np.float32(job.budget(3.0))
    key = jax.random.PRNGKey(seed)
    for s in (Settings(policy="lynceus", la=1, k_gh=2, refit="frozen"),
              Settings(policy="la0", la=0, k_gh=2)):
        nat = make_selector(job.space, job.unit_price, job.t_max, s)
        pad = make_selector(job.space.pad_to(bucket), job.unit_price,
                            job.t_max, s)
        i0, v0, _ = nat(key, y, mask, beta)
        yp, mp, _ = _padded_state(job, bucket, y, mask)
        i1, v1, _ = pad(key, yp, mp, beta)
        assert int(i0) == int(i1)
        assert bool(v0) == bool(v1)


@settings(deadline=None, max_examples=5)
@given(seed=st.integers(0, 30), extra=st.integers(0, 9))
def test_timeout_cap_padding_invariant(seed, extra):
    """τ is billed, not just compared: the padded selector must produce the
    native τ bit for bit (the 4-bit sigma quantization absorbs the padded
    program's fusion wobble)."""
    job = synthetic_job(seed, n_a=5, n_b=3)
    bucket = GeometryBucket(m=16 + extra, f=2, t=4)
    s = Settings(policy="la0", la=0, k_gh=2, timeout=True)
    y, mask = _observe(job, n=5, seed=seed)
    cens = np.zeros_like(mask)
    beta = np.float32(job.budget(3.0))
    key = jax.random.PRNGKey(seed)
    nat = make_selector(job.space, job.unit_price, job.t_max, s)
    pad = make_selector(job.space.pad_to(bucket), job.unit_price,
                        job.t_max, s)
    i0, _, d0 = nat(key, y, mask, beta, cens)
    yp, mp, cp = _padded_state(job, bucket, y, mask, cens)
    i1, _, d1 = pad(key, yp, mp, beta, cp)
    assert int(i0) == int(i1)
    assert float(np.asarray(d0["timeout"])) == float(np.asarray(d1["timeout"]))


def test_padded_selection_never_picks_padding_even_when_space_exhausted():
    """Every native point observed: the native selector stops (Γ empty) and
    so must the padded one — the padding tail is untested but must never
    become a candidate."""
    job = synthetic_job(0, n_a=3, n_b=2)
    m = job.space.n_points
    bucket = GeometryBucket(m=16, f=2, t=4)
    s = Settings(policy="la0", la=0, k_gh=2)
    y = job.cost.astype(np.float32)
    mask = np.ones(m, bool)
    pad = make_selector(job.space.pad_to(bucket), job.unit_price,
                        job.t_max, s)
    yp, mp, _ = _padded_state(job, bucket, y, mask)
    _, valid, _ = pad(jax.random.PRNGKey(0), yp, mp,
                      np.float32(job.budget(3.0)))
    assert not bool(valid), "padding lane entered the candidate set"


# --------------------------------------------------------------------------- #
# 2. masked decisions ignore the padding tail
# --------------------------------------------------------------------------- #
@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 100), m=st.integers(3, 10),
       pad=st.integers(1, 12))
def test_masked_argmax_and_budget_filter_ignore_padding(seed, m, pad):
    """Whatever values the padding tail carries — including a maximal
    score and an always-affordable posterior — a ``quantize_scores`` argmax
    over valid-masked scores and the Γ membership stay on native lanes."""
    rng = np.random.default_rng(seed)
    total = m + pad
    valid = np.zeros(total, bool)
    valid[:m] = True
    scores = rng.uniform(0.0, 1.0, total).astype(np.float32)
    scores[m:] = 2.0                       # adversarial: padding dominates
    masked = acq.quantize_scores(
        jnp.where(jnp.asarray(valid), jnp.asarray(scores), -jnp.inf))
    assert int(jnp.argmax(masked)) < m
    mu = np.full(total, 0.1, np.float32)   # everything looks affordable
    sigma = np.full(total, 0.01, np.float32)
    ok = np.asarray(acq.budget_ok(jnp.asarray(mu), jnp.asarray(sigma),
                                  jnp.float32(5.0)))
    gamma = ok & valid
    assert not gamma[m:].any()
    assert gamma[:m].all()


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 100), m=st.integers(3, 10),
       pad=st.integers(1, 12))
def test_masked_incumbent_ignores_padding_sigma(seed, m, pad):
    """No feasible observation: y* falls back to max-observed + 3·max-sigma
    over *untested* points.  A huge posterior spread on a padding lane must
    not leak into that fallback when the validity mask is supplied."""
    rng = np.random.default_rng(seed)
    total = m + pad
    valid = np.zeros(total, bool)
    valid[:m] = True
    y = np.zeros(total, np.float32)
    obs = np.zeros(total, bool)
    obs[0] = True
    y[0] = 1.0
    feas = np.zeros(total, bool)           # nothing feasible -> fallback
    sigma = np.full(total, 0.5, np.float32)
    sigma[m:] = 100.0                      # adversarial padding spread
    mu = np.ones(total, np.float32)
    got = float(acq.incumbent(jnp.asarray(y), jnp.asarray(obs),
                              jnp.asarray(feas), jnp.asarray(mu),
                              jnp.asarray(sigma), jnp.asarray(valid)))
    want = 1.0 + 3.0 * float(sigma[1:m].max()) if m > 1 else 1.0 + 3.0 * 0.5
    assert got == pytest.approx(want)
    assert got < 10.0, "padding sigma leaked into the incumbent fallback"


# --------------------------------------------------------------------------- #
# 3. bucket construction + validation
# --------------------------------------------------------------------------- #
def test_pad_to_rejects_narrow_bucket():
    space = DiscreteSpace.from_grid({"a": list(range(5)),
                                     "b": list(range(3))})
    m, f, t = space.geometry
    for bad in (GeometryBucket(m - 1, f, t), GeometryBucket(m, f - 1, t),
                GeometryBucket(m, f, t - 1)):
        with pytest.raises(ValueError, match="bucket"):
            space.pad_to(bad)
    with pytest.raises(ValueError, match="widths"):
        GeometryBucket(0, 1, 1)
    with pytest.raises(ValueError, match="integers"):
        GeometryBucket(32.5, 2, 4)
    assert GeometryBucket(32.0, 2, 4).m == 32      # exact floats coerce


def test_pad_to_preserves_native_values_bitwise():
    space = DiscreteSpace.from_grid({"a": list(range(5)),
                                     "b": [0.0, 2.5, 7.0]})
    m, f, t = space.geometry
    bucket = GeometryBucket(m=next_pow2(m), f=f + 2, t=t + 1)
    ps = space.pad_to(bucket)
    assert ps.n_points == bucket.m and ps.n_dims == bucket.f
    np.testing.assert_array_equal(ps.points[:m, :f], space.points)
    np.testing.assert_array_equal(ps.thresholds[:f, :t], space.thresholds)
    assert ps.valid[:m].all() and not ps.valid[m:].any()
    assert np.isinf(ps.thresholds[f:]).all()
    assert ps.native is space


def test_bucket_for_spaces_covers_members():
    spaces = [DiscreteSpace.from_grid({"a": list(range(a)),
                                       "b": list(range(b))})
              for a, b in ((3, 2), (5, 4), (4, 7))]
    bucket = GeometryBucket.for_spaces(spaces)
    assert bucket.m == next_pow2(max(s.n_points for s in spaces))
    assert bucket.f == max(s.n_dims for s in spaces)
    assert bucket.t == max(int(s.thresholds.shape[1]) for s in spaces)
    for s in spaces:
        s.pad_to(bucket)                   # must not raise
