"""Paper §4.4 extensions: multiple constraints + setup costs."""

import numpy as np
import pytest

from repro.core import Settings
from repro.core.extensions import (ConstrainedJob, cartesian_gh,
                                   default_setup_cost,
                                   optimize_multi_constraint,
                                   optimize_with_setup_costs)
from repro.core.space import DiscreteSpace
from repro.jobs import tensorflow_jobs
from repro.jobs.tables import JobTable


def _job(seed=0):
    rng = np.random.default_rng(seed)
    space = DiscreteSpace.from_grid({"vm_type": [0, 1, 2],
                                     "cluster_vcpus": [8, 16, 32, 64]})
    runtime = rng.uniform(0.1, 1.0, space.n_points)
    price = rng.uniform(0.5, 2.0, space.n_points)
    return JobTable("j", space, runtime, price,
                    t_max=float(np.quantile(runtime, 0.7)))


def test_cartesian_gh_weights_normalized():
    vals, wts = cartesian_gh([1.0, 2.0], [0.5, 0.3], k=3)
    assert vals.shape[1] == 2
    assert wts.sum() == pytest.approx(1.0)
    assert (wts > 0).all()


def test_cartesian_gh_pruning_reduces_branches():
    full, _ = cartesian_gh([0.0] * 3, [1.0] * 3, k=3, prune=0.0)
    pruned, w = cartesian_gh([0.0] * 3, [1.0] * 3, k=3, prune=0.05)
    assert pruned.shape[0] < full.shape[0] == 27
    assert w.sum() == pytest.approx(1.0)


def test_multi_constraint_respects_joint_feasibility():
    job = _job()
    rng = np.random.default_rng(1)
    energy = rng.uniform(0.0, 10.0, job.space.n_points)
    cjob = ConstrainedJob(job, {"energy": energy},
                          {"energy": float(np.quantile(energy, 0.6))})
    out = optimize_multi_constraint(cjob, budget_b=4.0, seed=0)
    assert out["cno"] >= 1.0
    # recommended config satisfies the extra constraint if any explored did
    arr = np.array(out["explored"])
    if cjob.feasible[arr].any():
        assert cjob.feasible[out["recommended"]]


def test_multi_constraint_timeout_censors_and_saves():
    """Timeout settings abort long runs: billed spend drops vs the uncapped
    twin, censored runs never get recommended, and the joint-constraint
    guarantee is preserved."""
    job = _job(2)
    rng = np.random.default_rng(5)
    energy = rng.uniform(0.0, 10.0, job.space.n_points)
    cjob = ConstrainedJob(job, {"energy": energy},
                          {"energy": float(np.quantile(energy, 0.6))})
    s = Settings(policy="la0", n_trees=10, depth=3, timeout=True,
                 timeout_tmax_mult=1.0)
    out = optimize_multi_constraint(cjob, budget_b=4.0, seed=0, settings=s)
    assert out["censored"], "t_max cap must censor on this landscape"
    assert out["recommended"] not in out["censored"]
    assert out["cno"] >= 1.0
    arr = np.array(out["explored"])
    if (cjob.feasible[arr] & ~np.isin(arr, out["censored"])).any():
        assert cjob.feasible[out["recommended"]]


def test_setup_cost_model():
    job = _job()
    setup = default_setup_cost(job.space, boot_fee=0.01)
    # first deployment boots everything
    assert setup(None, 0) == pytest.approx(0.01 * job.space.points_raw[0, 1])
    i8 = job.space.row_of([0, 8])
    i16 = job.space.row_of([0, 16])
    j8 = job.space.row_of([1, 8])
    # growing same type boots only the delta
    assert setup(i8, i16) == pytest.approx(0.01 * 8)
    # shrinking is free
    assert setup(i16, i8) == 0.0
    # type change reboots all
    assert setup(i8, j8) == pytest.approx(0.01 * 8)


def test_setup_costs_accounted_in_budget():
    job = _job()
    setup = default_setup_cost(job.space, boot_fee=0.05)
    out = optimize_with_setup_costs(job, Settings(policy="la0", n_trees=10,
                                                  depth=3),
                                    setup_cost=setup, budget_b=4.0, seed=0)
    assert out["setup_spent"] > 0.0
    assert out["cno"] >= 1.0
