"""Deterministic stand-in for ``hypothesis`` when it is not installed.

CI has no network, so the property-test modules must not hard-depend on
hypothesis.  This shim implements the tiny subset the suite uses —
``@settings`` (ignored), ``@given`` with keyword strategies, and
``st.floats / st.integers / st.sampled_from`` — by turning each ``@given``
into a plain ``pytest.mark.parametrize`` over a fixed grid of examples
drawn from each strategy (bounds plus interior points).  Coverage is
narrower than real hypothesis, but the properties still execute.
"""

from __future__ import annotations

import pytest


class _Strategy:
    def __init__(self, examples):
        self.examples = list(examples)


class st:
    """Mirror of ``hypothesis.strategies`` for the subset the tests use."""

    @staticmethod
    def floats(min_value, max_value):
        span = float(max_value) - float(min_value)
        return _Strategy([float(min_value) + span * f
                          for f in (0.0, 0.23, 0.5, 0.81, 1.0)])

    @staticmethod
    def integers(min_value, max_value):
        lo, hi = int(min_value), int(max_value)
        mid = (lo + hi) // 2
        return _Strategy(sorted({lo, min(lo + 1, hi), mid,
                                 max(hi - 1, lo), hi}))

    @staticmethod
    def sampled_from(elements):
        return _Strategy(elements)


def settings(**_kwargs):
    """No-op replacement for ``hypothesis.settings``."""
    return lambda fn: fn


def given(**strategies):
    """Parametrize over a cycled grid of each strategy's examples.

    Each parameter's cycle is rotated by its position so same-shaped
    strategies are decorrelated — e.g. two floats(-5, 5) arguments must not
    walk the grid in lockstep, or every example would sit on the degenerate
    mu == y_star diagonal and off-diagonal regressions would pass untested.
    """
    names = list(strategies)
    n_examples = max(len(s.examples) for s in strategies.values())
    if len(names) > 1:
        n_examples += len(names) - 1        # let the rotations play out
    rows = [tuple(strategies[n].examples[(i + p) % len(strategies[n].examples)]
                  for p, n in enumerate(names)) for i in range(n_examples)]
    if len(names) == 1:
        rows = [r[0] for r in rows]

    def deco(fn):
        return pytest.mark.parametrize(",".join(names), rows)(fn)

    return deco
