"""Optimization-loop invariants (paper Alg. 1) across all policies."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # no-network CI: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import Settings, optimize
from repro.core.metrics import cno_stats, nex_stats
from repro.jobs import scout_jobs
from repro.jobs.tables import JobTable
from repro.core.space import DiscreteSpace


def _tiny_job(seed=0, m=24):
    rng = np.random.default_rng(seed)
    space = DiscreteSpace.from_grid({"a": list(range(6)),
                                     "b": list(range(4))})
    runtime = rng.uniform(0.1, 2.0, space.n_points)
    price = rng.uniform(0.5, 2.0, space.n_points)
    return JobTable("tiny", space, runtime, price,
                    t_max=float(np.median(runtime)))


POLICIES = [("rnd", 0), ("bo", 0), ("la0", 0), ("lynceus", 1), ("lynceus", 2)]


@pytest.mark.parametrize("policy,la", POLICIES)
def test_invariants(policy, la):
    job = _tiny_job()
    out = optimize(job, Settings(policy=policy, la=la, k_gh=2),
                   budget_b=3.0, seed=1)
    # never explores the same config twice
    assert len(set(out.explored)) == len(out.explored)
    # bootstrap included
    assert out.nex >= job.bootstrap_size()
    # overshoot bounded by one config's cost (budget check precedes the run)
    assert out.spent <= out.budget + float(job.cost.max()) + 1e-6
    # recommendation is feasible if any explored config was feasible
    feas = job.feasible[np.array(out.explored)]
    if feas.any():
        assert job.feasible[out.recommended]
    # trajectory is monotone non-increasing
    t = np.asarray(out.trajectory)
    assert (np.diff(t) <= 1e-9).all()
    assert out.cno >= 1.0 - 1e-9


def test_same_bootstrap_shared_across_policies():
    job = _tiny_job()
    outs = {}
    for policy, la in POLICIES:
        outs[policy, la] = optimize(job, Settings(policy=policy, la=la,
                                                  k_gh=2),
                                    budget_b=2.0, seed=7)
    boots = {o.explored[:job.bootstrap_size()] for o in outs.values()}
    assert len(boots) == 1                      # identical i-th bootstrap


def test_lynceus_beats_rnd_on_average():
    """Qualitative paper claim (C1) — evaluated where the paper evaluates it:
    the large, sharp 384-config TensorFlow landscape.  (On the small Scout
    spaces ~45% of configs sit within 2x of the optimum, so RND is near-par
    there — consistent with the paper's own Fig 5 vs Fig 4 contrast.)"""
    from repro.jobs import tensorflow_jobs
    job = tensorflow_jobs(0)[0]
    s_lyn = Settings(policy="lynceus", la=1, k_gh=3, refit="frozen")
    s_rnd = Settings(policy="rnd")
    lyn = [optimize(job, s_lyn, budget_b=3.0, seed=s) for s in range(8)]
    rnd = [optimize(job, s_rnd, budget_b=3.0, seed=s) for s in range(8)]
    hit = lambda outs: np.mean([o.found_optimum for o in outs])
    # the paper's headline metric: probability of finding the optimum
    assert hit(lyn) > hit(rnd)
    assert np.median([o.cno for o in lyn]) <= np.median([o.cno for o in rnd])


def test_metrics_aggregation():
    job = _tiny_job()
    outs = [optimize(job, Settings(policy="rnd"), budget_b=2.0, seed=s)
            for s in range(5)]
    c = cno_stats(outs)
    n = nex_stats(outs)
    assert c["n"] == 5 and c["mean"] >= 1.0
    assert set(c) >= {"p50", "p90", "p95", "hit_rate"}
    assert n["mean"] >= job.bootstrap_size()


@settings(deadline=None, max_examples=5)
@given(seed=st.integers(0, 50), b=st.sampled_from([1.0, 3.0]))
def test_budget_scaling_increases_exploration(seed, b):
    job = _tiny_job(seed)
    lo = optimize(job, Settings(policy="rnd"), budget_b=1.0, seed=seed)
    hi = optimize(job, Settings(policy="rnd"), budget_b=5.0, seed=seed)
    assert hi.nex >= lo.nex
