"""Pallas kernels vs. pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import trees
from repro.core.acquisition import gauss_hermite
from repro.core.space import DiscreteSpace
from repro.kernels.decode_attention.kernel import decode_attention_call
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_call
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gh_ei.kernel import gh_ei_call
from repro.kernels.gh_ei.ref import gh_ei_ref
from repro.kernels.ssm_scan.kernel import ssm_scan_call
from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.tree_predict.kernel import tree_predict_call
from repro.kernels.tree_predict.ref import tree_predict_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,h,kh,s,t,d", [
    (2, 4, 2, 128, 128, 64),
    (1, 4, 1, 64, 128, 32),      # MQA, cross lengths
    (1, 6, 6, 128, 128, 16),     # MHA, odd head count
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None), (True, 32, None), (True, None, 30.0),
    (False, None, None),
])
def test_flash_attention_sweep(b, h, kh, s, t, d, dtype, causal, window,
                               softcap):
    q = jnp.asarray(RNG.normal(size=(b, h, s, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, kh, t, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, kh, t, d)), dtype)
    out = flash_attention_call(q, k, v, causal=causal, window=window,
                               softcap=softcap, bq=64, bk=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window,
                        softcap=softcap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,h,kh,t,d,pos,window", [
    (2, 4, 2, 256, 64, 100, None),
    (1, 8, 1, 512, 32, 900, None),    # ring rollover (pos > t)
    (2, 4, 4, 256, 64, 300, 64),      # sliding window
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, h, kh, t, d, pos, window, dtype):
    q = jnp.asarray(RNG.normal(size=(b, h, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, kh, t, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, kh, t, d)), dtype)
    out = decode_attention_call(q, k, v, pos, window=window, bk=128,
                                interpret=True)
    ref = decode_attention_ref(q, k, v, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("depth,n_trees,bm", [(2, 4, 16), (4, 10, 32),
                                              (5, 7, 64)])
def test_tree_predict_sweep(depth, n_trees, bm):
    space = DiscreteSpace.from_grid({"a": list(range(5)),
                                     "b": [0.0, 2.0, 7.0],
                                     "c": list(range(6))})
    y = jnp.asarray(RNG.normal(size=(space.n_points,)).astype(np.float32))
    mask = jnp.asarray(RNG.random(space.n_points) < 0.6)
    left = trees.make_left_table(space.points, space.thresholds)
    params, _ = trees.fit_forest(
        jax.random.PRNGKey(depth), y, mask, jnp.asarray(space.points), left,
        jnp.asarray(space.thresholds), n_trees=n_trees, depth=depth)
    x = jnp.asarray(space.points)
    mu_k, sig_k = tree_predict_call(x, params.feat, params.thr, params.leaf,
                                    bm=bm, interpret=True)
    mu_r, sig_r = tree_predict_ref(x, params.feat, params.thr, params.leaf)
    np.testing.assert_allclose(np.asarray(mu_k), np.asarray(mu_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(sig_k), np.asarray(sig_r),
                               atol=1e-5)


def test_tree_predict_consistent_with_core_forest():
    """Kernel must agree with the engine's own tabular predictions."""
    space = DiscreteSpace.from_grid({"a": list(range(8)),
                                     "b": list(range(8))})
    y = jnp.asarray(RNG.normal(size=(space.n_points,)).astype(np.float32))
    mask = jnp.asarray(RNG.random(space.n_points) < 0.5)
    left = trees.make_left_table(space.points, space.thresholds)
    params, assign = trees.fit_forest(
        jax.random.PRNGKey(0), y, mask, jnp.asarray(space.points), left,
        jnp.asarray(space.thresholds), n_trees=10, depth=4)
    preds = jnp.take_along_axis(params.leaf, assign, axis=1)
    mu_core, sig_core = trees.forest_mu_sigma(preds, 1e-6)
    mu_k, sig_k = tree_predict_call(jnp.asarray(space.points), params.feat,
                                    params.thr, params.leaf, bm=32,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(mu_k), np.asarray(mu_core),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(sig_k), np.asarray(sig_core),
                               atol=1e-5)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("m,k_gh,bm", [(97, 3, 32), (512, 5, 128), (33, 2, 64)])
def test_gh_ei_sweep(m, k_gh, bm):
    mu = jnp.asarray(RNG.uniform(1, 5, m), jnp.float32)
    sig = jnp.asarray(RNG.uniform(0.1, 2, m), jnp.float32)
    u = jnp.asarray(RNG.uniform(0.5, 3, m), jnp.float32)
    xi, _ = gauss_hermite(k_gh)
    a = gh_ei_call(mu, sig, u, 2.5, 1.2, 10.0, jnp.asarray(xi), bm=bm,
                   interpret=True)
    r = gh_ei_ref(mu, sig, u, 2.5, 1.2, 10.0, jnp.asarray(xi))
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(r[0]), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(r[1]))
    np.testing.assert_allclose(np.asarray(a[2]), np.asarray(r[2]), atol=1e-5)


def test_gh_ei_wrapper_censoring_pre_adjust():
    """The ops.gh_ei censoring path == censored_adjust then the plain call;
    an all-False mask reproduces the uncensored result bit for bit."""
    from repro.core import acquisition as acq
    from repro.kernels.gh_ei.ops import gh_ei

    m = 64
    mu = jnp.asarray(RNG.uniform(1, 5, m), jnp.float32)
    sig = jnp.asarray(RNG.uniform(0.1, 2, m), jnp.float32)
    u = jnp.asarray(RNG.uniform(0.5, 3, m), jnp.float32)
    y = jnp.asarray(RNG.uniform(2, 8, m), jnp.float32)
    cens = jnp.asarray(np.arange(m) % 7 == 0)
    xi, _ = gauss_hermite(3)
    xi = jnp.asarray(xi)

    plain = gh_ei(mu, sig, u, 2.5, 1.2, 10.0, xi, force="ref")
    none_c = gh_ei(mu, sig, u, 2.5, 1.2, 10.0, xi, force="ref",
                   cens=jnp.zeros(m, bool), y_cens=y)
    for a, b in zip(plain, none_c):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    censored = gh_ei(mu, sig, u, 2.5, 1.2, 10.0, xi, force="ref",
                     cens=cens, y_cens=y)
    mu_adj, sig_adj = acq.censored_adjust(mu, sig, y, cens, 0.5)
    expect = gh_ei(mu_adj, sig_adj, u, 2.5, 1.2, 10.0, xi, force="ref")
    for a, b in zip(censored, expect):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,l,h,n,p,chunk", [
    (2, 128, 3, 16, 8, 32), (1, 64, 2, 8, 8, 64), (1, 96, 1, 4, 16, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_sweep(b, l, h, n, p, chunk, dtype):
    k = jnp.asarray(RNG.normal(size=(b, l, h, n)) * 0.3, dtype)
    v = jnp.asarray(RNG.normal(size=(b, l, h, p)), dtype)
    q = jnp.asarray(RNG.normal(size=(b, l, h, n)) * 0.3, dtype)
    ld = -jnp.asarray(RNG.uniform(0.01, 0.5, (b, l, h)), jnp.float32)
    g = jnp.asarray(RNG.uniform(0, 1, (b, l, h)), jnp.float32)
    out = ssm_scan_call(k, v, q, ld, g, chunk=chunk, interpret=True)
    ref = ssm_scan_ref(k, v, q, ld, g, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=(5e-2 if dtype == jnp.bfloat16 else 1e-4),
                               rtol=5e-2)


# --------------------------------------------------------------------------- #
# Shared dispatch policy (kernels/dispatch.py)
# --------------------------------------------------------------------------- #
_DISPATCHED_OPS = ("flash_attention", "decode_attention", "ssm_scan",
                   "tree_predict", "gh_ei", "select_step")


def test_dispatch_decision_identical_across_ops(monkeypatch):
    """One auto policy for every op: Pallas on TPU *and* GPU, ref elsewhere
    — no per-op drift back to the old copy-pasted TPU-only force blocks."""
    from repro.kernels import dispatch
    for backend, want in [("tpu", "pallas"), ("gpu", "pallas"),
                          ("cpu", "ref"), ("METAL", "ref")]:
        monkeypatch.setattr(dispatch.jax, "default_backend",
                            lambda b=backend: b)
        monkeypatch.setattr(dispatch, "_degraded_logged", set())
        decisions = {op: dispatch.resolve_mode(None, op=op)
                     for op in _DISPATCHED_OPS}
        assert set(decisions.values()) == {want}, (backend, decisions)
    for mode in dispatch.MODES:            # force always wins
        assert dispatch.resolve_mode(mode, op="x") == mode
    with pytest.raises(ValueError, match="force"):
        dispatch.resolve_mode("cuda", op="x")


def test_dispatch_logs_degrade_once_per_op(monkeypatch, caplog):
    import logging
    from repro.kernels import dispatch
    monkeypatch.setattr(dispatch.jax, "default_backend", lambda: "cpu")
    monkeypatch.setattr(dispatch, "_degraded_logged", set())
    with caplog.at_level(logging.INFO, logger="repro.kernels"):
        for _ in range(3):
            dispatch.resolve_mode(None, op="gh_ei")
        dispatch.resolve_mode(None, op="tree_predict")
    degrades = [r for r in caplog.records if "degrading" in r.message]
    assert len(degrades) == 2              # once per op, not per call


# --------------------------------------------------------------------------- #
# Fused selector step vs the unfused program: bit parity incl. diagnostics
# --------------------------------------------------------------------------- #
def _selector_job(seed=0):
    from repro.jobs.tables import JobTable
    rng = np.random.default_rng(seed)
    space = DiscreteSpace.from_grid({"a": list(range(5)),
                                     "b": list(range(3))})
    runtime = rng.uniform(0.1, 1.0, space.n_points)
    price = rng.uniform(0.5, 2.0, space.n_points)
    return JobTable("j", space, runtime, price,
                    t_max=float(np.median(runtime)))


def _selector_obs(job, n=6, seed=0):
    rng = np.random.default_rng(seed)
    idx = rng.choice(job.space.n_points, n, replace=False)
    y = np.zeros(job.space.n_points, np.float32)
    mask = np.zeros(job.space.n_points, bool)
    y[idx] = job.cost[idx]
    mask[idx] = True
    cens = np.zeros(job.space.n_points, bool)
    cens[idx[0]] = True
    return y, mask, cens


def _run_selector(job, space, s, y, mask, cens, beta, key):
    """Run the bound selector on ``space`` (native or padded); returns
    (idx, valid, diagnostics as numpy) restricted to native lanes."""
    from repro.core import make_selector
    m = space.n_points
    native = job.space.n_points
    u = np.zeros(m, np.float32)
    u[:native] = job.unit_price
    yp = np.zeros(m, np.float32)
    yp[:native] = y
    mp = np.zeros(m, bool)
    mp[:native] = mask
    cp = None
    if cens is not None:
        cp = np.zeros(m, bool)
        cp[:native] = cens
    sel = make_selector(space, u, job.t_max, s)
    idx, valid, diag = sel(key, yp, mp, beta, cens=cp)
    trim = lambda a: (np.asarray(a)[:native] if np.ndim(a) >= 1
                      else np.asarray(a))
    return int(idx), bool(valid), {k: trim(v) for k, v in diag.items()}


@pytest.mark.parametrize("policy,la", [("bo", 0), ("la0", 0), ("lynceus", 1)])
@pytest.mark.parametrize("timeout", [False, True])
@pytest.mark.parametrize("padded", [False, True])
def test_fused_selector_bit_parity(policy, la, timeout, padded):
    """The fused kernel program must replay the unfused selector bit for bit
    — decision, valid flag, and every diagnostic (incl. the billed timeout
    cap) — on native and geometry-bucket-padded spaces alike."""
    from repro.core import Settings
    from repro.core.space import GeometryBucket
    job = _selector_job(3)
    y, mask, cens = _selector_obs(job, seed=3)
    cens = cens if timeout else None
    space = (job.space.pad_to(GeometryBucket(m=32, f=3, t=6))
             if padded else job.space)
    beta = job.budget(3.0)
    key = jax.random.PRNGKey(7)
    out = {}
    for mode in ("ref", "interpret"):
        s = Settings(policy=policy, la=la, k_gh=2, n_trees=3, depth=3,
                     timeout=timeout, fused_selector=mode)
        out[mode] = _run_selector(job, space, s, y, mask, cens, beta, key)
    (ia, va, da), (ib, vb, db) = out["ref"], out["interpret"]
    assert (ia, va) == (ib, vb)
    assert sorted(da) == sorted(db)
    if timeout:
        assert "timeout" in da
    for k in da:
        np.testing.assert_array_equal(da[k], db[k], err_msg=k)
        assert da[k].tobytes() == db[k].tobytes(), k   # bitwise, not just ==
