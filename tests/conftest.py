"""Shared fixtures. Tests must see exactly 1 CPU device (never set
xla_force_host_platform_device_count here — that is dryrun.py's job)."""

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session", autouse=True)
def _single_device_guard():
    # dry-run env leakage would silently change sharding tests
    assert len(jax.devices()) == 1, "tests must run with 1 device"
    yield
