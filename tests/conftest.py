"""Shared fixtures.  The suite runs on 4 virtual CPU devices: the flag is
set here, before anything imports jax, so the sharded-service tests
(``tests/test_sharded_service.py``, ``tests/test_placement.py``) exercise
real multi-device placement.  dryrun.py still sets its own (larger) count
inside its own subprocess."""

import os

# Appended last so it wins over any inherited device-count flag; must run
# before the jax import below (the backend reads it at first init).
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4"
                           ).strip()

import jax  # noqa: E402  (after the flag, on purpose)
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session", autouse=True)
def _device_count_guard():
    # The flag above must win: shard placement tests depend on exactly 4
    # devices, and silent env leakage would change what they test.
    assert len(jax.devices()) == 4, "tests must run with 4 virtual devices"
    yield
