"""Dry-run launcher: subprocess test (needs 512 forced host devices).

Slow (one real compile); exercises mesh construction, input specs,
sharding rules, lower+compile, and the roofline JSON artifact end-to-end
for one cheap cell on BOTH the single-pod and multi-pod meshes.
"""

import json
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_dryrun_cell_single_and_multi(tmp_path):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-125m",
         "--shape", "decode_32k", "--mesh", "both", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, r.stderr[-2000:]
    for mesh in ("single", "multi"):
        f = tmp_path / f"xlstm-125m__decode_32k__{mesh}.json"
        d = json.loads(f.read_text())
        assert "error" not in d, d.get("error")
        assert d["chips"] == (256 if mesh == "single" else 512)
        assert d["hlo_flops_per_device"] > 0
        assert d["roofline"]["bound"] in ("compute", "memory", "collective")


@pytest.mark.slow
def test_skip_cells_are_documented(tmp_path):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "hubert-xlarge", "--shape", "decode_32k", "--mesh", "single",
         "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=300)
    assert r.returncode == 0
    d = json.loads((tmp_path / "hubert-xlarge__decode_32k__single.json"
                    ).read_text())
    assert "skipped" in d and "encoder-only" in d["skipped"]


def test_roofline_parser_units():
    from repro.launch.roofline import parse_collectives, roofline_terms
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups=[32,16]<=[512], dimensions={0}
  %ar = f32[4096]{0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = bf16[8,128]{1,0} reduce-scatter(%z), replica_groups=[2,256]<=[512], dimensions={0}
  %cp = bf16[64]{0} collective-permute(%w), source_target_pairs={{0,1}}
"""
    st = parse_collectives(hlo)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "reduce-scatter": 1, "collective-permute": 1}
    ag = 16 * 1024 * 2
    ar = 4096 * 4
    rs = 8 * 128 * 2
    cp = 64 * 2
    expect = (ag * 15 / 16) + (2 * ar * 3 / 4) + (rs * 255) + cp
    assert st.wire_bytes_per_device == pytest.approx(expect)
    t = roofline_terms(197e12, 819e9, 50e9)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)
    assert t["step_s"] == pytest.approx(1.0)
