"""Observability layer: recorder mechanics, spans, exporters, forensics —
and the zero-perturbation pin: a trace-on service replays the trace-off
service bit for bit, ``spend_trajectory`` included.

The flight recorder watches the streaming service's lifecycle; it must
never join the decision path.  These tests pin both halves: the obs
machinery itself (ring bounds, full-history counts, JSONL round trips,
the validators' teeth against known-bad sequences) and the contract that
turning it on changes nothing the determinism contract covers.
"""

import json
import threading

import pytest

from repro.core import RunRequest, Settings, run_queue
from repro.obs import (EVENT_KINDS, PHASES, PINNED_OUTCOME_FIELDS,
                       TERMINAL_KINDS, Event, FlightRecorder, diff_outcomes,
                       dump_divergence, metrics_to_prometheus, phase_span,
                       read_trace_jsonl, validate_lifecycle, validate_trace,
                       write_trace_jsonl)
from repro.service import ServiceConfig, StreamingTuner
from tests.test_batched_harness import (_assert_outcomes_equal,
                                        _distinct_geometry_jobs)


# --------------------------------------------------------------------------- #
# FlightRecorder mechanics
# --------------------------------------------------------------------------- #
def test_recorder_ring_bounds_and_full_history_counts():
    rec = FlightRecorder(capacity=4)
    for i in range(10):
        rec.emit("submit", ticket=i)
    assert len(rec) == 4
    assert rec.dropped == 6
    assert [e.ticket for e in rec.events()] == [6, 7, 8, 9]
    # counts survive ring eviction — that is the counter-balance side
    assert rec.counts() == {"submit": 10}
    rec.clear()
    assert len(rec) == 0 and rec.counts() == {} and rec.dropped == 0
    rec.emit("submit", ticket=99)
    assert rec.events()[0].seq == 11, "seq must never be reused after clear"


def test_recorder_rejects_unknown_kind_and_bad_capacity():
    rec = FlightRecorder()
    with pytest.raises(ValueError, match="unknown event kind"):
        rec.emit("teleport", ticket=1)
    with pytest.raises(ValueError, match="capacity"):
        FlightRecorder(capacity=0)


def test_disabled_recorder_is_a_no_op():
    rec = FlightRecorder(enabled=False)
    rec.emit("submit", ticket=1)
    rec.emit("nonsense-not-even-validated")   # disabled: not even checked
    assert len(rec) == 0 and rec.counts() == {}


def test_recorder_seq_and_time_monotone_under_threads():
    rec = FlightRecorder(capacity=10_000)

    def hammer(tid):
        for _ in range(200):
            rec.emit("stage", ticket=tid)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert validate_trace(rec.events()) == []
    assert rec.counts()["stage"] == 800


def test_event_jsonl_round_trip(tmp_path):
    rec = FlightRecorder()
    rec.emit("seat", ticket=3, slot=1, segment=2, via="host")
    rec.emit("dispatch", segment=2, steps=5, busy=8)
    path = rec.dump_jsonl(tmp_path / "trace.jsonl")
    back = read_trace_jsonl(path)
    assert back == rec.events()
    assert back[0].data == {"via": "host"}
    # and the writer helper produces the identical artifact
    p2 = write_trace_jsonl(rec.events(), tmp_path / "t2.jsonl")
    assert p2.read_text() == path.read_text()
    assert json.loads(path.read_text().splitlines()[0])["kind"] == "seat"


def test_terminal_kinds_are_event_kinds():
    assert TERMINAL_KINDS <= EVENT_KINDS
    assert "span" in EVENT_KINDS and "dispatch" in EVENT_KINDS


# --------------------------------------------------------------------------- #
# phase_span
# --------------------------------------------------------------------------- #
def test_phase_span_times_and_attributes_compiles():
    rec = FlightRecorder()
    with phase_span(rec, "dispatch", segment=0, compiles=True):
        pass
    (e,) = rec.events()
    assert e.kind == "span" and e.data["phase"] == "dispatch"
    assert e.data["dur_s"] >= 0.0
    # cache deltas: nothing compiled inside an empty body
    assert e.data["episode_compiles"] == 0
    assert e.data["selector_compiles"] == 0


def test_phase_span_emits_even_when_body_raises():
    rec = FlightRecorder()
    with pytest.raises(RuntimeError):
        with phase_span(rec, "device_block"):
            raise RuntimeError("crashed dispatch")
    (e,) = rec.events()
    assert e.data["phase"] == "device_block"


def test_phase_span_rejects_unknown_phase_and_skips_disabled():
    with pytest.raises(ValueError, match="unknown phase"):
        with phase_span(FlightRecorder(), "warp"):
            pass
    rec = FlightRecorder(enabled=False)
    with phase_span(rec, "seat"):
        pass
    with phase_span(None, "seat"):
        pass
    assert len(rec) == 0


def test_phase_vocabulary_matches_cycle_order():
    assert PHASES == ("seat", "inject", "dispatch", "device_block",
                      "harvest")


# --------------------------------------------------------------------------- #
# Validators' teeth (known-bad sequences must be flagged)
# --------------------------------------------------------------------------- #
def _ev(seq, kind, ticket=None, **data):
    return Event(seq=seq, t=float(seq), kind=kind, ticket=ticket, data=data)


def test_validate_trace_flags_schema_violations():
    bad = [
        Event(seq=1, t=1.0, kind="nope"),
        Event(seq=1, t=0.5, kind="submit"),          # seq + time regress
        Event(seq=2, t=0.6, kind="span", data={"phase": "warp"}),
        Event(seq=3, t=0.7, kind="dispatch"),        # no segment/steps
        Event(seq=4, t=0.8, kind="seat"),            # no ticket
    ]
    issues = validate_trace(bad)
    for frag in ("unknown kind", "seq not increasing", "backwards",
                 "unknown phase", "without a segment", "without a ticket"):
        assert any(frag in i for i in issues), frag


def test_validate_lifecycle_flags_ordering_violations():
    seat_without_admit = [_ev(1, "seat", ticket=1)]
    resolve_after_cancel = [
        _ev(1, "submit", ticket=1), _ev(2, "admit", ticket=1),
        _ev(3, "cancel_request", ticket=1), _ev(4, "cancel", ticket=1),
        _ev(5, "resolve", ticket=1),
    ]
    cancel_unrequested = [_ev(1, "submit", ticket=1),
                          _ev(2, "cancel", ticket=1)]
    resume_unpreempted = [
        _ev(1, "submit", ticket=1), _ev(2, "admit", ticket=1),
        _ev(3, "stage", ticket=1), _ev(4, "seat", ticket=1),
        _ev(5, "resume", ticket=1),
    ]
    assert any("from state 'new'" in i
               for i in validate_lifecycle(seat_without_admit))
    assert any("after a terminal" in i
               for i in validate_lifecycle(resolve_after_cancel))
    assert any("without a prior cancel_request" in i
               for i in validate_lifecycle(cancel_unrequested))
    assert any("without a prior preempt" in i
               for i in validate_lifecycle(resume_unpreempted))


def test_validate_lifecycle_accepts_the_full_happy_path():
    good = [
        _ev(1, "submit", ticket=1), _ev(2, "admit", ticket=1),
        _ev(3, "stage", ticket=1), _ev(4, "inject", ticket=1),
        _ev(5, "seat", ticket=1), _ev(6, "evict", ticket=1),
        _ev(7, "preempt", ticket=1), _ev(8, "stage", ticket=1),
        _ev(9, "seat", ticket=1), _ev(10, "resume", ticket=1),
        _ev(11, "harvest", ticket=1), _ev(12, "resolve", ticket=1),
    ]
    assert validate_lifecycle(good, require_terminal=True) == []
    # an undrained ticket only fails under require_terminal
    pending = good[:5]
    assert validate_lifecycle(pending) == []
    assert any("never reached a terminal" in i
               for i in validate_lifecycle(pending, require_terminal=True))


# --------------------------------------------------------------------------- #
# Exporters + forensics
# --------------------------------------------------------------------------- #
def test_prometheus_rendering_types_and_values():
    from repro.service.metrics import MetricsRecorder
    rec = MetricsRecorder(lane_slots=2)
    rec.record_submit()
    rec.record_resolve(0.5, nex=4)
    text = metrics_to_prometheus(rec.snapshot())
    assert "# TYPE lynceus_service_resolved counter" in text
    assert "# TYPE lynceus_service_lane_occupancy gauge" in text
    assert "lynceus_service_resolved 1" in text
    assert "lynceus_service_latency_floor_s 0.5" in text
    # every line is either a TYPE annotation or "<series> <float>"
    for line in text.strip().splitlines():
        if not line.startswith("# TYPE "):
            name, value = line.split()
            assert name.startswith("lynceus_service_")
            float(value)


def test_diff_outcomes_and_divergence_artifact(tmp_path):
    class O:
        def __init__(self, nex, spent):
            self.explored, self.recommended, self.cno = (1, 2), 2, 0.5
            self.nex, self.spent, self.budget = nex, spent, 3.0
            self.found_optimum, self.censored = True, set()
            self.trajectory, self.spend_trajectory = (0.5,), (spent,)

    a, b = O(2, 1.0), O(3, 1.5)
    assert diff_outcomes([a], [a]) == []
    diffs = diff_outcomes([a], [b])
    assert any("nex differs" in d for d in diffs)
    assert any("spend_trajectory differs" in d for d in diffs)

    rec = FlightRecorder()
    rec.emit("submit", ticket=1)
    p0 = dump_divergence("unit", expected=[a], actual=[b], recorder=rec,
                         context={"suite": "test_obs"}, out_dir=tmp_path)
    p1 = dump_divergence("unit", expected=[a], actual=[b],
                         out_dir=tmp_path)
    assert p0 != p1, "repeated failures must not overwrite each other"
    art = json.loads(p0.read_text())
    assert art["diffs"] and art["context"] == {"suite": "test_obs"}
    assert art["expected"][0]["nex"] == 2 and art["actual"][0]["nex"] == 3
    assert art["flight_record"][0]["kind"] == "submit"
    assert set(art["expected"][0]) == set(PINNED_OUTCOME_FIELDS)


# --------------------------------------------------------------------------- #
# The zero-perturbation pin + an end-to-end traced service
# --------------------------------------------------------------------------- #
_JOBS = _distinct_geometry_jobs()
_REQS = [RunRequest(_JOBS[r % 3], seed=770 + r,
                    budget_b=4.0 if r % 2 == 0 else 1.5) for r in range(6)]
_SETTINGS = Settings(policy="lynceus", la=1, k_gh=2, refit="frozen")


def _drive(cfg: ServiceConfig) -> tuple[list, StreamingTuner]:
    svc = StreamingTuner(_JOBS, _SETTINGS, cfg)
    tickets = []
    for i, r in enumerate(_REQS):
        tickets.append(svc.submit(r, priority=i % 2))
        if i % 2:
            svc.pump()
    svc.drain()
    return [t.result() for t in tickets], svc


def test_trace_on_replays_trace_off_bit_for_bit():
    """The acceptance pin: a full streaming run with tracing enabled
    yields outcomes bit-identical to the trace-off run AND to the
    sequential oracle — spend_trajectory included via the shared
    comparator.  The recorder watches; it never perturbs."""
    base = dict(lane_slots=2, queue_capacity=3, step_quota=6, high_water=0)
    off, _ = _drive(ServiceConfig(**base))
    on, svc = _drive(ServiceConfig(**base, trace=True))
    _assert_outcomes_equal(off, on, recorder=svc.recorder,
                           tag="trace_on_vs_off")
    _assert_outcomes_equal(run_queue(_REQS, _SETTINGS), on,
                           recorder=svc.recorder, tag="trace_on_vs_oracle")
    assert len(svc.flight_record()) > 0


def test_traced_service_record_is_valid_and_complete(tmp_path):
    """End-to-end over the real service: the trace passes both validators
    (terminal for every ticket), covers every lifecycle stage the drive
    exercised, spans cover every phase, and the JSONL dump round-trips."""
    cfg = ServiceConfig(lane_slots=2, queue_capacity=3, step_quota=6,
                        high_water=0, trace=True, trace_capacity=8192)
    outs, svc = _drive(cfg)
    events = svc.flight_record()
    assert validate_trace(events) == []
    assert validate_lifecycle(events, require_terminal=True) == []
    counts = svc.recorder.counts()
    assert counts["submit"] == counts["admit"] == len(_REQS)
    assert counts["resolve"] == counts["harvest"] == len(_REQS)
    assert counts["dispatch"] >= 1
    phases = {e.data["phase"] for e in events if e.kind == "span"}
    assert phases == set(PHASES)
    # dispatch spans carry compile attribution (deltas are >= 0; the
    # programs may already sit in the global cache from earlier tests)
    disp = [e for e in events if e.kind == "span"
            and e.data["phase"] == "dispatch"]
    assert all(e.data["episode_compiles"] >= 0
               and e.data["selector_compiles"] >= 0 for e in disp)
    path = svc.dump_trace(tmp_path / "svc.jsonl")
    assert read_trace_jsonl(path) == events


def test_untraced_service_records_nothing():
    outs, svc = _drive(ServiceConfig(lane_slots=2, queue_capacity=3,
                                     step_quota=6))
    assert svc.flight_record() == []
    assert svc.recorder.counts() == {}


def test_trace_profiler_requires_trace():
    with pytest.raises(ValueError, match="trace_profiler requires"):
        ServiceConfig(trace_profiler=True)
    cfg = ServiceConfig(lane_slots=2, queue_capacity=3, step_quota=6,
                        trace=True, trace_profiler=True)
    svc = StreamingTuner(_JOBS[:1], _SETTINGS, cfg)
    t = svc.submit(_REQS[0])
    svc.drain()
    assert t.result().nex > 0       # profiler scopes are naming only


def test_obs_report_renders_a_real_trace(tmp_path, capsys):
    """scripts/obs_report.py over a real drained-service trace: exit 0,
    every section present, and the validator gate trips on a corrupted
    trace (nonzero exit)."""
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                           / "scripts"))
    import obs_report
    cfg = ServiceConfig(lane_slots=2, queue_capacity=3, step_quota=6,
                        trace=True)
    _, svc = _drive(cfg)
    path = svc.dump_trace(tmp_path / "trace.jsonl")
    argv = sys.argv
    try:
        sys.argv = ["obs_report.py", str(path), "--require-terminal"]
        assert obs_report.main() == 0
        out = capsys.readouterr().out
        for frag in ("0 issue(s)", "per-ticket timeline",
                     "per-slot occupancy", "phase spans"):
            assert frag in out
        # corrupt the trace: resolve for a ticket that never submitted
        with path.open("a") as f:
            f.write(json.dumps({"seq": 10**6, "t": 10.0**6,
                                "kind": "resolve", "ticket": 424242}) + "\n")
        sys.argv = ["obs_report.py", str(path)]
        assert obs_report.main() == 1
    finally:
        sys.argv = argv
